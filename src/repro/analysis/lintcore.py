"""Shared AST lint framework + the ruff-fallback rule set.

The framework half (``Finding``, ``Rule``, ``check_source``, ``run_paths``)
is rule-agnostic: a rule inspects one parsed module and yields findings;
the driver parses each file once, runs every rule, and applies ``# noqa``
suppression with ruff's semantics — a bare ``# noqa`` suppresses every
rule on that line, ``# noqa: F401`` (or ``# noqa: F401, JAX02``) only the
named codes.

The rule half is the network-free subset of ``ruff check`` that CI gates
(tools/astlint.py delegates here, so the shim and the framework cannot
drift): syntax errors (E9), unused imports (F401), duplicate top-level
definitions (F811), and f-strings without placeholders (F541). F401
resolves re-exports from the *parsed* ``__all__`` assignment list — not a
textual ``"__all__" in source`` check, which let any file merely
mentioning ``__all__`` in a docstring or comment skip unused-import
detection entirely.

The JAX-aware rules (JAX01-JAX04) live in ``repro.analysis.astchecks``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Union

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "tools")

# bare `# noqa` (group "codes" empty) or `# noqa: C1[, C2...]`
_NOQA_RE = re.compile(
    r"#\s*noqa(?::\s*(?P<codes>[A-Z]+[0-9]+(?:\s*,\s*[A-Z]+[0-9]+)*))?",
    re.IGNORECASE,
)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint finding, pinned to (path, line, code)."""

    path: str
    line: int
    code: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.msg}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def to_github(self) -> str:
        """GitHub Actions workflow-command form — printed to stdout in
        CI, the finding renders as an inline PR annotation."""
        msg = self.msg.replace("%", "%25").replace("\r", "%0D")
        msg = msg.replace("\n", "%0A")
        return (f"::error file={self.path},line={self.line},"
                f"title={self.code}::{msg}")


class Rule:
    """One lint rule: inspect a parsed module, yield findings.

    ``code`` is the rule's primary finding code (used in listings); a rule
    may emit findings under several codes as long as each Finding carries
    its own. ``# noqa`` filtering happens in the driver — rules should
    report every violation they see.
    """

    code: str = "?"

    def check(self, tree: ast.AST, source: str, path: str) -> Iterable[Finding]:
        raise NotImplementedError


def noqa_map(source: str) -> Dict[int, Optional[frozenset]]:
    """1-based line -> suppressed codes (None = every code, ruff's bare noqa)."""
    out: Dict[int, Optional[frozenset]] = {}
    for i, ln in enumerate(source.splitlines()):
        m = _NOQA_RE.search(ln)
        if m is None:
            continue
        codes = m.group("codes")
        if codes is None:
            out[i + 1] = None
        else:
            out[i + 1] = frozenset(c.strip().upper() for c in codes.split(","))
    return out


def is_suppressed(noqa: Dict[int, Optional[frozenset]], line: int, code: str) -> bool:
    if line not in noqa:
        return False
    codes = noqa[line]
    return codes is None or code.upper() in codes


def check_source(
    path: str, source: str, rules: Sequence[Rule]
) -> List[Finding]:
    """Parse one module and run every rule; noqa-filtered, line-ordered."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, "E9", f"syntax error: {e.msg}")]
    noqa = noqa_map(source)
    findings: List[Finding] = []
    for rule in rules:
        for f in rule.check(tree, source, path):
            if not is_suppressed(noqa, f.line, f.code):
                findings.append(f)
    return sorted(findings)


def iter_py_files(paths: Sequence[Union[str, Path]]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        p = Path(p)
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    return files


def run_paths(
    paths: Sequence[Union[str, Path]], rules: Sequence[Rule]
) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(check_source(str(f), f.read_text(), rules))
    return findings


# ---------------------------------------------------------------------------
# The ruff-fallback rules (the astlint subset)
# ---------------------------------------------------------------------------


def used_names(tree: ast.AST) -> set:
    """Names referenced anywhere, with dotted access rooted: np.zeros -> np."""
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            n: ast.AST = node
            while isinstance(n, ast.Attribute):
                n = n.value
            if isinstance(n, ast.Name):
                used.add(n.id)
    return used


def dunder_all_names(tree: ast.AST) -> set:
    """String entries of every ``__all__`` assignment / extension.

    Parsed from the AST — a docstring or comment mentioning ``__all__``
    contributes nothing. Handles ``__all__ = [...]``, ``__all__ += [...]``
    and ``__all__.extend([...])`` / ``__all__.append("x")`` forms.
    """
    names: set = set()

    def literal_strings(node: Optional[ast.AST]):
        if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
            for elt in node.elts:
                if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                    names.add(elt.value)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            names.add(node.value)

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            if any(isinstance(t, ast.Name) and t.id == "__all__"
                   for t in node.targets):
                literal_strings(node.value)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            t = node.target
            if isinstance(t, ast.Name) and t.id == "__all__":
                literal_strings(node.value)
        elif isinstance(node, ast.Call):
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id == "__all__"
                    and fn.attr in ("extend", "append")):
                for arg in node.args:
                    literal_strings(arg)
    return names


class UnusedImportRule(Rule):
    """F401: imported name never used and not re-exported via __all__."""

    code = "F401"

    def check(self, tree, source, path):
        used = used_names(tree)
        exported = dunder_all_names(tree)
        noqa = noqa_map(source)
        for node in ast.walk(tree):
            if not isinstance(node, (ast.Import, ast.ImportFrom)):
                continue
            if isinstance(node, ast.ImportFrom) and node.module == "__future__":
                continue
            # a noqa anywhere in a multi-line import statement covers every
            # alias in it (the directive sits on the opening line while the
            # names wrap onto the next)
            span = range(node.lineno, (node.end_lineno or node.lineno) + 1)
            if any(is_suppressed(noqa, ln, "F401") for ln in span):
                continue
            for alias in node.names:
                if alias.name == "*":
                    continue
                bound = alias.asname or alias.name
                name = bound.split(".")[0]
                if name in used or bound in exported or name in exported:
                    continue
                yield Finding(
                    path, alias.lineno, "F401",
                    f"unused import: {alias.asname or alias.name}")


class EmptyFStringRule(Rule):
    """F541: f-string without placeholders."""

    code = "F541"

    def check(self, tree, source, path):
        # format specs (f"{x:8.3f}") parse as nested JoinedStr nodes with
        # no FormattedValue of their own — they are not F541
        spec_ids = {id(node.format_spec) for node in ast.walk(tree)
                    if isinstance(node, ast.FormattedValue) and node.format_spec}
        for node in ast.walk(tree):
            if isinstance(node, ast.JoinedStr) and id(node) not in spec_ids:
                if not any(isinstance(v, ast.FormattedValue)
                           for v in node.values):
                    yield Finding(path, node.lineno, "F541",
                                  "f-string without placeholders")


class RedefinitionRule(Rule):
    """F811: duplicate top-level def/class names."""

    code = "F811"

    def check(self, tree, source, path):
        seen: Dict[str, int] = {}
        for node in tree.body if isinstance(tree, ast.Module) else []:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                if node.name in seen:
                    yield Finding(
                        path, node.lineno, "F811",
                        f"redefinition of {node.name!r} "
                        f"(first at line {seen[node.name]})")
                seen[node.name] = node.lineno


RUFF_FALLBACK_RULES = (UnusedImportRule(), EmptyFStringRule(),
                       RedefinitionRule())
