"""JAX-aware AST lint rules (JAX01-JAX04) on the lintcore framework.

Rule table (docs/design.md §8):

  JAX01  PRNG key reuse: the same key variable consumed by two key-first
         calls without an intervening ``split``/``fold_in`` — correlated
         randomness (two samplers fed the same key produce dependent
         draws; two *stages* fed the same key silently share entropy).
  JAX02  host sync inside jitted code: ``.item()``, ``float(param)`` /
         ``int(param)`` / ``bool(param)`` on a traced argument, or any
         ``np.*`` call in a ``@jax.jit``-decorated body — each forces a
         device->host transfer (or a trace error) on the hot path.
  JAX03  jitted function takes a known-static parameter (``scan``,
         ``k``, ``n_probe``, ``ef_search``, ``bits``, ...) that is not
         declared in ``static_argnames`` — every distinct value then
         either fails tracing (non-hashable configs) or bloats the
         compile cache instead of specializing.
  JAX04  bare ``lax.top_k`` outside the streaming scan engine: top_k
         crashes when k exceeds the input length, so call sites must
         either route through core/scan.py's sentinel-padded merge or
         carry a ``# noqa: JAX04`` with the static k <= N argument.
  JAX05  blocking host-sync inside an ``async def`` body:
         ``block_until_ready``, ``.item()``, or ``np.asarray``/
         ``np.array`` on device values stall the event loop for the
         device round-trip — on the serving path that head-of-line
         blocks every coalesced request behind one transfer. Move the
         sync into the executor-side compute function (where the PR 2
         batcher already runs device work) or ``# noqa: JAX05`` calls
         that only touch host data.

All rules are deliberately heuristic (AST-only, no imports executed):
false positives are expected to be rare and suppressed with a
code-specific ``# noqa: JAXxx`` plus a justification comment.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lintcore import Finding, Rule

# jax.random.* callees that derive/construct keys rather than consume them
_KEY_DERIVERS = {"split", "fold_in", "PRNGKey", "key", "key_data",
                 "wrap_key_data", "clone"}
# function parameters the repo treats as jit-static by contract
KNOWN_STATIC_PARAMS = frozenset({
    "scan", "k", "n_probe", "ef_search", "bits", "block_docs", "block_n",
    "impl", "interpret", "p", "config", "n_list",
})
# the one module whose top_k merge owns the k <= N guarantee
SCAN_ENGINE_SUFFIX = ("core/scan.py", "core\\scan.py")


def _call_root(func: ast.AST) -> Optional[str]:
    """Leftmost name of a call target: jax.random.normal -> jax."""
    n = func
    while isinstance(n, ast.Attribute):
        n = n.value
    return n.id if isinstance(n, ast.Name) else None


def _call_attr(func: ast.AST) -> Optional[str]:
    """Final attribute of a call target: jax.random.normal -> normal."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _scopes(tree: ast.AST):
    """Yield (scope_node, own_statements) for the module and each function.

    Nested function bodies are excluded from the enclosing scope's
    statements (they get their own scope), so a key captured by a closure
    is analyzed where it is *used*, not double-counted.
    """
    def own_nodes(scope) -> List[ast.AST]:
        out: List[ast.AST] = []
        stack = list(ast.iter_child_nodes(scope))
        while stack:
            node = stack.pop(0)
            out.append(node)
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                stack.extend(ast.iter_child_nodes(node))
        return out

    yield tree, own_nodes(tree)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, own_nodes(node)


class PRNGKeyReuseRule(Rule):
    """JAX01: a key variable consumed twice without a split between."""

    code = "JAX01"

    def check(self, tree, source, path) -> Iterable[Finding]:
        for _scope, nodes in _scopes(tree):
            # key variables: names assigned from jax.random.{PRNGKey,key,
            # fold_in} or unpacked from jax.random.split
            events: List[Tuple[int, int, str, str]] = []
            for node in nodes:
                if isinstance(node, ast.Assign):
                    val = node.value
                    is_key_maker = (
                        isinstance(val, ast.Call)
                        and _call_root(val.func) in ("jax", "random")
                        and _call_attr(val.func) in ("PRNGKey", "key",
                                                     "fold_in", "split",
                                                     "clone"))
                    for tgt in node.targets:
                        names = (tgt.elts if isinstance(
                            tgt, (ast.Tuple, ast.List)) else [tgt])
                        for t in names:
                            if isinstance(t, ast.Name):
                                kind = "mk" if is_key_maker else "clear"
                                events.append((node.lineno, node.col_offset,
                                               kind, t.id))
                elif isinstance(node, ast.Call):
                    attr = _call_attr(node.func)
                    if attr in _KEY_DERIVERS:
                        continue
                    if node.args and isinstance(node.args[0], ast.Name):
                        events.append((node.lineno, node.col_offset, "use",
                                       node.args[0].id))
            events.sort()
            live: Dict[str, int] = {}   # key name -> first-use line
            for line, _col, kind, name in events:
                if kind == "mk":
                    live[name] = 0
                elif kind == "clear":
                    live.pop(name, None)
                elif kind == "use" and name in live:
                    first = live[name]
                    if first:
                        yield Finding(
                            path, line, "JAX01",
                            f"PRNG key {name!r} reused (first consumed at "
                            f"line {first}); split or fold_in between uses")
                    else:
                        live[name] = line


def _jit_decorator(dec: ast.AST) -> Optional[ast.Call]:
    """Return the decorator Call if `dec` is a jax.jit application.

    Recognized forms: @jax.jit / @jit (returns None-call marker via a
    synthetic empty Call), @partial(jax.jit, ...) and
    @functools.partial(jax.jit, ...), @jax.jit(...) directly.
    """
    if isinstance(dec, (ast.Name, ast.Attribute)):
        if _call_attr(dec) == "jit":
            return ast.Call(func=dec, args=[], keywords=[])
        return None
    if isinstance(dec, ast.Call):
        if _call_attr(dec.func) == "jit":
            return dec
        if _call_attr(dec.func) == "partial" and dec.args:
            if _call_attr(dec.args[0]) == "jit":
                return dec
    return None


def _static_argnames(call: ast.Call) -> Optional[Set[str]]:
    """Declared static_argnames strings; None if undeterminable."""
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            return None  # positional statics: be permissive, skip the rule
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List, ast.Set)):
                out = set()
                for elt in v.elts:
                    if not (isinstance(elt, ast.Constant)
                            and isinstance(elt.value, str)):
                        return None
                    out.add(elt.value)
                return out
            return None
    return set()


def _jitted_functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                call = _jit_decorator(dec)
                if call is not None:
                    yield node, call
                    break


def _numpy_aliases(tree: ast.AST) -> Set[str]:
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "numpy":
                    aliases.add(a.asname or "numpy")
    return aliases


class HostSyncRule(Rule):
    """JAX02: device->host sync inside a jitted function body."""

    code = "JAX02"

    def check(self, tree, source, path) -> Iterable[Finding]:
        np_names = _numpy_aliases(tree)
        for fn, _call in _jitted_functions(tree):
            params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)}
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"):
                    yield Finding(
                        path, node.lineno, "JAX02",
                        f".item() inside jitted {fn.name!r} forces a host "
                        "sync; keep the value on device")
                elif _call_root(node.func) in np_names:
                    yield Finding(
                        path, node.lineno, "JAX02",
                        f"numpy call inside jitted {fn.name!r} materializes "
                        "on host (np.* does not trace); use jnp")
                elif (isinstance(node.func, ast.Name)
                      and node.func.id in ("float", "int", "bool")
                      and len(node.args) == 1
                      and isinstance(node.args[0], ast.Name)
                      and node.args[0].id in params):
                    yield Finding(
                        path, node.lineno, "JAX02",
                        f"{node.func.id}() on traced argument "
                        f"{node.args[0].id!r} inside jitted {fn.name!r} "
                        "host-syncs (or fails under trace)")


class MissingStaticArgRule(Rule):
    """JAX03: jitted function with an undeclared known-static parameter."""

    code = "JAX03"

    def check(self, tree, source, path) -> Iterable[Finding]:
        for fn, call in _jitted_functions(tree):
            declared = _static_argnames(call)
            if declared is None:
                continue
            params = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)]
            missing = [p for p in params
                       if p in KNOWN_STATIC_PARAMS and p not in declared]
            if missing:
                yield Finding(
                    path, fn.lineno, "JAX03",
                    f"jitted {fn.name!r} takes known-static "
                    f"{sorted(missing)} but static_argnames omits "
                    "them (recompile-per-value or unhashable-trace risk)")


class BareTopKRule(Rule):
    """JAX04: lax.top_k outside the sentinel-padded scan engine."""

    code = "JAX04"

    def check(self, tree, source, path) -> Iterable[Finding]:
        if path.replace("\\", "/").endswith(SCAN_ENGINE_SUFFIX[0]):
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if _call_attr(node.func) != "top_k":
                continue
            root = _call_root(node.func)
            if root not in ("lax", "jax"):
                continue
            yield Finding(
                path, node.lineno, "JAX04",
                "bare lax.top_k crashes when k > input length; route "
                "through core/scan.py's padded merge, or add "
                "`# noqa: JAX04` with the static k <= N argument")


class AsyncHostSyncRule(Rule):
    """JAX05: blocking device sync on the event loop (async def body).

    Only a function's *own* statements are checked: a sync helper
    defined inside an ``async def`` and handed to
    ``run_in_executor`` is exactly the right place for these calls, and
    ``_scopes`` already separates it into its own (non-async) scope.
    """

    code = "JAX05"

    def check(self, tree, source, path) -> Iterable[Finding]:
        np_names = _numpy_aliases(tree)
        for scope, nodes in _scopes(tree):
            if not isinstance(scope, ast.AsyncFunctionDef):
                continue
            for node in nodes:
                if not isinstance(node, ast.Call):
                    continue
                attr = _call_attr(node.func)
                if attr == "block_until_ready":
                    yield Finding(
                        path, node.lineno, "JAX05",
                        f"block_until_ready in async {scope.name!r} stalls "
                        "the event loop for a device sync; await it from "
                        "an executor instead")
                elif (attr == "item"
                      and isinstance(node.func, ast.Attribute)):
                    yield Finding(
                        path, node.lineno, "JAX05",
                        f".item() in async {scope.name!r} blocks the event "
                        "loop on a device->host transfer; move it into "
                        "the executor-side compute")
                elif (_call_root(node.func) in np_names
                      and attr in ("asarray", "array")):
                    yield Finding(
                        path, node.lineno, "JAX05",
                        f"np.{attr} in async {scope.name!r} blocks the "
                        "event loop if the value lives on device; move "
                        "the transfer into the executor-side compute, or "
                        "`# noqa: JAX05` if the input is host data")


JAX_RULES = (PRNGKeyReuseRule(), HostSyncRule(), MissingStaticArgRule(),
             BareTopKRule(), AsyncHostSyncRule())
