"""Jaxpr budget analyzer: the memory envelope as a checked contract.

HPC-ColPali's value proposition is that compression keeps the search hot
path inside a fixed envelope: peak scan memory O(B * Mq * block * Md),
corpus-proportional allocations bounded by the code payload itself. PR 5
guaranteed that for exactly one entry point with a hand-written jaxpr
walk; this module generalizes the walk into a library driven by the
declarative manifests in ``repro.analysis.manifests``.

For each manifest the analyzer traces the registered entry point twice —
at corpus size ``n`` and at ``n_alt`` (both multiples of the scan block,
so the traced program structure is identical) — walks the closed jaxpr
including every sub-jaxpr nested in ``pjit`` / ``scan`` / ``while`` /
``cond`` equation params, and classifies every intermediate:

  * **static** (same bytes at both sizes): must fit
    ``max_block_bytes`` — the blocked-scan working set;
  * **N-scaling** (bytes grow with the corpus): the growth per document
    must stay under ``max_bytes_per_doc`` — enough for doc ids, validity
    masks and code payload handling, never enough for an O(N * Mq)
    score matrix or a decoded float corpus.

One exemption: *input views* — chains of ``slice`` / ``squeeze`` /
``reshape`` / ``transpose`` rooted at the traced inputs (e.g. hnsw
slicing one level of its (levels, N, 2m) adjacency) are bounded by the
index structure itself, alias or fuse in XLA, and say nothing about the
compute envelope; they are skipped. ``gather`` is deliberately NOT a
view: the unblocked ``table[:, :, codes]`` expansion is exactly what the
budget exists to catch.

Output dtypes are checked against the manifest (hamming scores stay
int32, doc ids stay int32 — the sentinel contract is dtype-stable).
Tracing is shape-symbolic (``jax.ShapeDtypeStruct``): a 2^20-document
corpus costs no memory to analyze.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

import jax
import numpy as np
from jax import core as jax_core

# primitives whose output is a (possibly aliased) relayout of one operand
VIEW_PRIMS = frozenset({"slice", "squeeze", "reshape", "transpose"})

__all__ = [
    "BudgetViolation",
    "analyze_manifest",
    "intermediate_avals",
    "iter_jaxprs",
    "max_intermediate_bytes",
]


@dataclasses.dataclass(frozen=True)
class BudgetViolation:
    """One manifest-contract violation."""

    manifest: str
    kind: str        # "block_bytes" | "n_scaling" | "dtype" | "structure"
    detail: str

    def __str__(self) -> str:
        return f"[{self.manifest}] {self.kind}: {self.detail}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def iter_jaxprs(jaxpr) -> Iterable:
    """Yield a jaxpr and every jaxpr nested in its eqn params.

    Descends into pjit (``jaxpr`` param), scan/while/cond (``jaxpr`` /
    ``cond_jaxpr`` / ``body_jaxpr`` / ``branches``) and any other
    primitive carrying Jaxpr or ClosedJaxpr values in its params.
    """
    yield jaxpr
    for eqn in jaxpr.eqns:
        for p in eqn.params.values():
            vals = p if isinstance(p, (tuple, list)) else (p,)
            for v in vals:
                inner = getattr(v, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from iter_jaxprs(inner)      # ClosedJaxpr
                elif hasattr(v, "eqns"):               # bare Jaxpr
                    yield from iter_jaxprs(v)


def _aval_bytes(aval) -> Optional[int]:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return None                                    # tokens etc.
    n = int(np.prod(shape, dtype=np.int64)) if len(shape) else 1
    return n * np.dtype(dtype).itemsize


def intermediate_avals(closed) -> List[Tuple[str, object, bool]]:
    """(primitive_name, out_aval, is_input_view) per eqn output.

    The traversal order is deterministic for a fixed traced program
    structure, so two traces of the same Python code at different corpus
    sizes pair positionally. ``is_input_view`` marks outputs of
    ``VIEW_PRIMS`` chains rooted at jaxpr inputs/constants — exempt from
    the budgets (see module docstring).
    """
    out: List[Tuple[str, object, bool]] = []
    for j in iter_jaxprs(closed.jaxpr):
        views = {id(v) for v in j.invars} | {id(v) for v in j.constvars}
        for eqn in j.eqns:
            is_view = (eqn.primitive.name in VIEW_PRIMS
                       and all(isinstance(x, jax_core.Literal)
                               or id(x) in views for x in eqn.invars))
            for v in eqn.outvars:
                if is_view:
                    views.add(id(v))
                out.append((eqn.primitive.name, v.aval, is_view))
    return out


def max_intermediate_bytes(closed) -> int:
    """Largest single non-view intermediate (PR 5's metric)."""
    worst = 0
    for _prim, aval, is_view in intermediate_avals(closed):
        b = _aval_bytes(aval)
        if b is not None and not is_view:
            worst = max(worst, b)
    return worst


def _fmt(prim: str, aval, nbytes: int) -> str:
    return (f"{prim} -> {getattr(aval, 'str_short', lambda: aval)()} "
            f"({nbytes / 2**20:.1f} MiB)")


def analyze_manifest(manifest) -> List[BudgetViolation]:
    """Check one ``BudgetManifest``; returns violations (empty = clean).

    Traces ``manifest.trace(n)`` at ``manifest.n`` and ``manifest.n_alt``
    and applies the growth classification described in the module
    docstring. If the two traces disagree structurally (different eqn
    count — e.g. a ragged tail block at one size only), a "structure"
    violation is reported and the single-trace fallback rule is applied:
    every intermediate must fit ``max_block_bytes`` OR cost at most
    ``max_bytes_per_doc`` per document.
    """
    name = manifest.name
    out: List[BudgetViolation] = []

    fn_big, args_big = manifest.trace(manifest.n)
    closed_big = jax.make_jaxpr(fn_big)(*args_big)
    fn_small, args_small = manifest.trace(manifest.n_alt)
    closed_small = jax.make_jaxpr(fn_small)(*args_small)

    # -- output dtype contracts ---------------------------------------------
    out_avals = [v.aval for v in closed_big.jaxpr.outvars]
    want = manifest.out_dtypes
    if want is not None:
        got = tuple(np.dtype(getattr(a, "dtype", None)).name
                    for a in out_avals)
        want_names = tuple(np.dtype(d).name for d in want)
        if got != want_names:
            out.append(BudgetViolation(
                name, "dtype",
                f"output dtypes {got} != declared {want_names}"))

    ints_big = intermediate_avals(closed_big)
    ints_small = intermediate_avals(closed_small)
    dn = manifest.n - manifest.n_alt

    if len(ints_big) != len(ints_small):
        out.append(BudgetViolation(
            name, "structure",
            f"trace at n={manifest.n} has {len(ints_big)} intermediates vs "
            f"{len(ints_small)} at n_alt={manifest.n_alt}; growth "
            "classification degraded to the single-trace rule (pick n / "
            "n_alt that keep the traced structure identical)"))
        for prim, aval, is_view in ints_big:
            b = _aval_bytes(aval)
            if b is None or is_view or b <= manifest.max_block_bytes:
                continue
            if b / manifest.n > manifest.max_bytes_per_doc:
                out.append(BudgetViolation(
                    name, "block_bytes",
                    f"{_fmt(prim, aval, b)} exceeds max_block_bytes="
                    f"{manifest.max_block_bytes / 2**20:.0f} MiB and "
                    f"{b / manifest.n:.1f} B/doc > max_bytes_per_doc="
                    f"{manifest.max_bytes_per_doc}"))
        return out

    for (prim, a_big, is_view), (_p2, a_small, _v2) in zip(ints_big,
                                                          ints_small):
        b_big, b_small = _aval_bytes(a_big), _aval_bytes(a_small)
        if b_big is None or b_small is None or is_view:
            continue
        if b_big == b_small:
            # static working set: the blocked-scan envelope
            if b_big > manifest.max_block_bytes:
                out.append(BudgetViolation(
                    name, "block_bytes",
                    f"static intermediate {_fmt(prim, a_big, b_big)} "
                    f"exceeds max_block_bytes="
                    f"{manifest.max_block_bytes / 2**20:.0f} MiB"))
        else:
            per_doc = (b_big - b_small) / dn
            if per_doc > manifest.max_bytes_per_doc:
                out.append(BudgetViolation(
                    name, "n_scaling",
                    f"N-scaling intermediate {_fmt(prim, a_big, b_big)} "
                    f"grows {per_doc:.1f} B/doc > max_bytes_per_doc="
                    f"{manifest.max_bytes_per_doc} (an O(N*Mq) score "
                    "matrix or decoded corpus is sneaking back in)"))
    return out


def report(manifest) -> dict:
    """Machine-readable summary for one manifest (jaxlint --json)."""
    violations = analyze_manifest(manifest)
    fn, args = manifest.trace(manifest.n)
    closed = jax.make_jaxpr(fn)(*args)
    return {
        "manifest": manifest.name,
        "n": manifest.n,
        "max_block_bytes": manifest.max_block_bytes,
        "max_bytes_per_doc": manifest.max_bytes_per_doc,
        "worst_intermediate_bytes": max_intermediate_bytes(closed),
        "n_intermediates": len(intermediate_avals(closed)),
        "violations": [v.to_json() for v in violations],
        "ok": not violations,
    }
