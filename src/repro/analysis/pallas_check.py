"""Static Pallas kernel verifier: VMEM, tiling and dtype contracts.

Every ``pl.pallas_call`` in ``src/repro/kernels/`` encodes hardware
contracts that used to live in docstrings and bare asserts: the one-hot
``(block_docs*Md, K)`` ADC tile "fits in VMEM for K <= 512", the corpus
axis "must divide by block_docs", the output "is f32". This module
checks them *statically* — no TPU, no Mosaic lowering — for every
registered kernel geometry (``kernel_sites``: the manifest trace
geometry, the serving-scale geometry, and the documented envelope), and
for planted test fixtures.

Capture is two-pass and backend-free:

  1. ``pl.pallas_call`` is temporarily replaced by a shim that records
     each call's grid, BlockSpecs (block shape, index map, memory
     space), out_shape and operand avals, then returns zeros of the
     declared out_shape; the entry point runs under ``jax.eval_shape``
     so nothing executes.
  2. The unpatched entry point is traced with ``jax.make_jaxpr``; each
     ``pallas_call`` equation's kernel jaxpr is walked for in-kernel
     temporaries (the one-hot expansion, similarity buffers — the part
     BlockSpecs alone cannot see). The two passes pair in call order.

Rules (each finding anchors at the kernel function's def site):

  PAL01  VMEM overflow — per-grid-step footprint
         ``DOUBLE_BUFFER * sum(VMEM block bytes) + sum(non-view kernel
         temporaries)`` exceeds ``kernels.vmem.VMEM_BUDGET_BYTES``.
         SMEM blocks are excluded from the VMEM sum.
  PAL02  tiling — an operand/output dimension is not divisible by its
         BlockSpec block size (the grid would drop trailing rows).
  PAL03  coverage — enumerating the grid, some output block is never
         written or is written more than once (racy/partial output).
  PAL04  dtype — an output dtype differs from the site's declared
         contract (e.g. a kernel silently accumulating in bf16).

``tools/jaxlint.py --pallas`` runs every registered site and fails CI
on any finding.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import Counter
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.analysis.jaxpr_budget import VIEW_PRIMS, iter_jaxprs
from repro.analysis.lintcore import Finding
from repro.kernels import vmem

__all__ = [
    "BlockInfo",
    "CapturedCall",
    "KernelSite",
    "capture_calls",
    "check_all",
    "check_site",
    "kernel_sites",
]

# grid sizes beyond this are spot-checked per-axis instead of fully
# enumerated for PAL03 (registered sites are far below it)
_MAX_GRID_ENUM = 1 << 16

# kernel-jaxpr primitives that do not allocate a new VMEM temporary:
# relayouts plus ref access (get/swap read/write the block buffers that
# the BlockSpec sum already prices)
_KERNEL_FREE_PRIMS = VIEW_PRIMS | {"get", "swap", "broadcast_in_dim"}


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    """One BlockSpec resolved against its operand/output aval."""

    block_shape: Tuple[int, ...]
    array_shape: Tuple[int, ...]
    dtype: str
    memory_space: str
    index_map: Optional[Callable]

    @property
    def is_smem(self) -> bool:
        return "smem" in self.memory_space.lower()

    @property
    def block_bytes(self) -> int:
        n = int(np.prod([d or 1 for d in self.block_shape],
                        dtype=np.int64)) if self.block_shape else 1
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class CapturedCall:
    """One pl.pallas_call site: specs from the shim, temporaries from
    the jaxpr pass (``kernel_tmp_bytes``)."""

    kernel_name: str
    path: str
    line: int
    grid: Tuple[int, ...]
    in_blocks: Tuple[BlockInfo, ...]
    out_blocks: Tuple[BlockInfo, ...]
    kernel_tmp_bytes: int = 0

    def vmem_bytes(self) -> int:
        blocks = sum(b.block_bytes
                     for b in self.in_blocks + self.out_blocks
                     if not b.is_smem)
        return vmem.DOUBLE_BUFFER * blocks + self.kernel_tmp_bytes


@dataclasses.dataclass(frozen=True)
class KernelSite:
    """One registered kernel geometry to verify.

    ``build()`` returns ``(fn, args)`` with ``jax.ShapeDtypeStruct``
    args — the same symbolic-trace convention as the budget manifests.
    ``out_dtypes`` is the declared output dtype contract.
    """

    name: str
    build: Callable[[], Tuple[Callable, tuple]]
    out_dtypes: Tuple[str, ...]
    notes: str = ""


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    return tuple(x) if isinstance(x, (tuple, list)) else (x,)


def _block_info(spec, operand) -> BlockInfo:
    shape = tuple(getattr(operand, "shape", ()))
    dtype = np.dtype(getattr(operand, "dtype", np.float32)).name
    if spec is None:
        return BlockInfo(shape, shape, dtype, "any", None)
    bs = tuple(getattr(spec, "block_shape", None) or shape)
    return BlockInfo(bs, shape, dtype,
                     str(getattr(spec, "memory_space", "") or ""),
                     getattr(spec, "index_map", None))


def capture_calls(fn, args) -> List[CapturedCall]:
    """Run both capture passes on one entry point; see module docstring."""
    records: List[dict] = []
    real = pl.pallas_call

    def shim(kernel, *, out_shape, grid=None, in_specs=None,
             out_specs=None, **_kw):
        def runner(*operands):
            ops = tuple(jax.ShapeDtypeStruct(o.shape, o.dtype)
                        for o in operands)
            outs = _as_tuple(out_shape)
            records.append({
                "kernel": kernel,
                "grid": _as_tuple(grid),
                "in_blocks": tuple(
                    _block_info(s, o)
                    for s, o in zip(_as_tuple(in_specs) or
                                    (None,) * len(ops), ops)),
                "out_blocks": tuple(
                    _block_info(s, o)
                    for s, o in zip(_as_tuple(out_specs) or
                                    (None,) * len(outs), outs)),
            })
            zeros = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)
            return zeros
        return runner

    # the kernels are @jax.jit entry points: each pass must re-trace, or
    # the shim pass's cached (pallas-free) trace would be served to the
    # jaxpr pass and vice versa
    jax.clear_caches()
    pl.pallas_call = shim
    try:
        jax.eval_shape(fn, *args)
    finally:
        pl.pallas_call = real

    # pass 2: the real trace, for in-kernel temporaries
    jax.clear_caches()
    tmp_bytes: List[int] = []
    closed = jax.make_jaxpr(fn)(*args)
    for j in iter_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            if eqn.primitive.name != "pallas_call":
                continue
            total = 0
            kernel_jaxpr = eqn.params.get("jaxpr")
            if kernel_jaxpr is not None:
                for kj in iter_jaxprs(getattr(kernel_jaxpr, "jaxpr",
                                              kernel_jaxpr)):
                    for keqn in kj.eqns:
                        if keqn.primitive.name in _KERNEL_FREE_PRIMS:
                            continue
                        for v in keqn.outvars:
                            aval = getattr(v, "aval", None)
                            shape = getattr(aval, "shape", None)
                            dtype = getattr(aval, "dtype", None)
                            if shape is None or dtype is None:
                                continue
                            n = int(np.prod(shape, dtype=np.int64)) \
                                if len(shape) else 1
                            total += n * np.dtype(dtype).itemsize
            tmp_bytes.append(total)

    if len(tmp_bytes) != len(records):          # pragma: no cover
        tmp_bytes = tmp_bytes[:len(records)] + \
            [0] * (len(records) - len(tmp_bytes))

    out: List[CapturedCall] = []
    for rec, tmp in zip(records, tmp_bytes):
        kernel = rec["kernel"]
        code = getattr(kernel, "__code__", None)
        path = getattr(code, "co_filename", "<unknown>")
        try:
            path = str(Path(path).resolve().relative_to(Path.cwd()))
        except ValueError:
            pass
        out.append(CapturedCall(
            kernel_name=getattr(kernel, "__name__", "<kernel>"),
            path=path,
            line=getattr(code, "co_firstlineno", 1),
            grid=rec["grid"],
            in_blocks=rec["in_blocks"],
            out_blocks=rec["out_blocks"],
            kernel_tmp_bytes=tmp,
        ))
    return out


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

def _check_vmem(call: CapturedCall, site: str,
                budget: int) -> List[Finding]:
    total = call.vmem_bytes()
    if total <= budget:
        return []
    blocks = total - call.kernel_tmp_bytes
    return [Finding(
        call.path, call.line, "PAL01",
        f"[{site}] {call.kernel_name}: per-grid-step VMEM footprint "
        f"{total / vmem.MiB:.2f} MiB (blocks x{vmem.DOUBLE_BUFFER} = "
        f"{blocks / vmem.MiB:.2f} MiB + kernel temporaries "
        f"{call.kernel_tmp_bytes / vmem.MiB:.2f} MiB) exceeds the "
        f"{budget / vmem.MiB:.0f} MiB budget")]


def _check_divisibility(call: CapturedCall, site: str) -> List[Finding]:
    out: List[Finding] = []
    for kind, blocks in (("operand", call.in_blocks),
                         ("output", call.out_blocks)):
        for idx, b in enumerate(blocks):
            if b.is_smem or len(b.block_shape) != len(b.array_shape):
                continue
            for d, (arr, blk) in enumerate(zip(b.array_shape,
                                               b.block_shape)):
                blk = blk or 1
                if blk and arr % blk:
                    out.append(Finding(
                        call.path, call.line, "PAL02",
                        f"[{site}] {call.kernel_name}: {kind} {idx} dim "
                        f"{d} has size {arr}, not divisible by block "
                        f"{blk} — the grid drops the trailing "
                        f"{arr % blk} row(s)"))
    return out


def _check_coverage(call: CapturedCall, site: str) -> List[Finding]:
    out: List[Finding] = []
    grid = call.grid
    if not grid:
        return out
    n_steps = int(np.prod(grid, dtype=np.int64))
    if n_steps > _MAX_GRID_ENUM:
        return out                               # registered sites are small
    steps = list(itertools.product(*[range(g) for g in grid]))
    for idx, b in enumerate(call.out_blocks):
        if b.index_map is None or len(b.block_shape) != len(b.array_shape):
            continue
        want = set(itertools.product(*[
            range(max(1, arr // (blk or 1)))
            for arr, blk in zip(b.array_shape, b.block_shape)]))
        seen = Counter(tuple(int(c) for c in _as_tuple(b.index_map(*s)))
                       for s in steps)
        missing = want - set(seen)
        multi = {c: n for c, n in seen.items() if c in want and n > 1}
        stray = set(seen) - want
        if missing:
            ex = sorted(missing)[:3]
            out.append(Finding(
                call.path, call.line, "PAL03",
                f"[{site}] {call.kernel_name}: output {idx} has "
                f"{len(missing)} block(s) never written (e.g. {ex}) — "
                f"those regions hold uninitialized memory"))
        if multi:
            c, n = sorted(multi.items())[0]
            out.append(Finding(
                call.path, call.line, "PAL03",
                f"[{site}] {call.kernel_name}: output {idx} block {c} "
                f"written {n} times ({len(multi)} block(s) multi-written)"
                f" — last-write-wins is order-dependent"))
        if stray:
            out.append(Finding(
                call.path, call.line, "PAL03",
                f"[{site}] {call.kernel_name}: output {idx} index map "
                f"addresses {len(stray)} block(s) outside the array "
                f"(e.g. {sorted(stray)[:3]})"))
    return out


def _check_dtypes(call: CapturedCall, site: str,
                  want: Tuple[str, ...]) -> List[Finding]:
    got = tuple(b.dtype for b in call.out_blocks)
    want_n = tuple(np.dtype(d).name for d in want)
    if got == want_n:
        return []
    return [Finding(
        call.path, call.line, "PAL04",
        f"[{site}] {call.kernel_name}: output dtypes {got} != declared "
        f"contract {want_n}")]


def check_site(site: KernelSite, *,
               budget: int = vmem.VMEM_BUDGET_BYTES) -> List[Finding]:
    """All findings for one registered kernel geometry."""
    fn, args = site.build()
    findings: List[Finding] = []
    for call in capture_calls(fn, args):
        findings += _check_vmem(call, site.name, budget)
        findings += _check_divisibility(call, site.name)
        findings += _check_coverage(call, site.name)
        findings += _check_dtypes(call, site.name, site.out_dtypes)
    return findings


def check_all(sites: Optional[Sequence[KernelSite]] = None, *,
              budget: int = vmem.VMEM_BUDGET_BYTES) -> List[Finding]:
    out: List[Finding] = []
    for site in (sites if sites is not None else kernel_sites()):
        out += check_site(site, budget=budget)
    return out


# ---------------------------------------------------------------------------
# The repo registry: every production kernel at its real geometries
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _qmaxsim_site(name: str, *, b: int, mq: int, k: int, md: int,
                  block: int, notes: str = "") -> KernelSite:
    """One ADC-kernel geometry; ``block`` is the scan block (the pallas
    call scores one scan block per invocation), the inner doc tile is
    picked exactly as core/scan.py picks it (VMEM-aware)."""
    def build():
        from repro.core.scan import _kernel_tile
        from repro.kernels import quantized_maxsim as qk
        tile = _kernel_tile(
            block, 32,
            fits=lambda t: vmem.fits(qk.qmaxsim_vmem_bytes(t, mq, k, md)))

        def fn(table, qm, codes, dm):
            return qk.quantized_maxsim_pallas(table, qm, codes, dm,
                                              block_docs=tile)
        return fn, (_sds((b, mq, k), jnp.float32),
                    _sds((b, mq), jnp.float32),
                    _sds((block, md), jnp.int32),
                    _sds((block, md), jnp.float32))
    return KernelSite(name, build, ("float32",), notes)


def _maxsim_site(name: str, *, b: int, mq: int, md: int, d: int,
                 block: int, notes: str = "") -> KernelSite:
    def build():
        from repro.core.scan import _kernel_tile
        from repro.kernels import maxsim as mk
        tile = _kernel_tile(
            block, 16,
            fits=lambda t: vmem.fits(mk.maxsim_vmem_bytes(t, mq, md, d)))

        def fn(q, qm, docs, dm):
            return mk.maxsim_pallas(q, qm, docs, dm, block_docs=tile)
        return fn, (_sds((b, mq, d), jnp.float32),
                    _sds((b, mq), jnp.float32),
                    _sds((block, md, d), jnp.float32),
                    _sds((block, md), jnp.float32))
    return KernelSite(name, build, ("float32",), notes)


def _hamming_site(name: str, *, b: int, mq: int, md: int,
                  block: int, notes: str = "") -> KernelSite:
    def build():
        from repro.core.scan import _kernel_tile
        from repro.kernels import hamming as hk
        tile = _kernel_tile(
            block, 64,
            fits=lambda t: vmem.fits(hk.hamming_vmem_bytes(t, mq, md)))

        def fn(qc, qm, dc, dm):
            return hk.hamming_maxsim_pallas(qc, qm, dc, dm, bits=8,
                                            block_docs=tile)
        return fn, (_sds((b, mq), jnp.int32),
                    _sds((b, mq), jnp.float32),
                    _sds((block, md), jnp.int32),
                    _sds((block, md), jnp.float32))
    return KernelSite(name, build, ("float32",), notes)


def _kmeans_site(name: str, *, n: int, k: int, d: int, block_n: int,
                 notes: str = "") -> KernelSite:
    def build():
        from repro.kernels import kmeans_assign as ka

        def fn(x, c):
            return ka.kmeans_assign_pallas(x, c, block_n=block_n)
        return fn, (_sds((n, d), jnp.float32), _sds((k, d), jnp.float32))
    return KernelSite(name, build, ("int32",), notes)


_SITES: Tuple[KernelSite, ...] = (
    _qmaxsim_site("qmaxsim_manifest", b=8, mq=8, k=256, md=16, block=256,
                  notes="the budget manifests' trace geometry"),
    _qmaxsim_site("qmaxsim_serving", b=8, mq=32, k=256, md=128, block=256,
                  notes="serving-scale geometry (ladder max batch)"),
    _qmaxsim_site("qmaxsim_k512", b=8, mq=32, k=512, md=128, block=256,
                  notes="the docstring's K<=512 envelope — the formerly "
                        "unchecked bound; the VMEM-aware tile picker "
                        "must shrink the doc tile to fit"),
    _maxsim_site("maxsim_manifest", b=8, mq=8, md=16, d=16, block=256),
    _maxsim_site("maxsim_serving", b=8, mq=32, md=64, d=128, block=256,
                 notes="the docstring's worked VMEM example"),
    _hamming_site("hamming_manifest", b=8, mq=8, md=16, block=256),
    _hamming_site("hamming_serving", b=8, mq=32, md=128, block=256),
    _kmeans_site("kmeans_assign_default", n=1024, k=256, d=128,
                 block_n=256),
    _kmeans_site("kmeans_assign_k512", n=1024, k=512, d=128, block_n=256,
                 notes="codebook at its documented 512x128 ceiling"),
)


def kernel_sites() -> Tuple[KernelSite, ...]:
    """Every registered production-kernel geometry (stable order)."""
    return _SITES
