"""Recompile sentry: jit-cache growth as a checked contract.

The serving ladder's whole point is a *closed* set of compiled shapes —
B in the power-of-two ladder times the query geometries actually served.
jax.jit enforces none of that: a float that arrives weak-typed one call
and strong-typed the next, a knob that should be static but traces, or a
batch that skipped the ladder padding each mint a fresh executable, and
the cache grows without bound while p99 eats the compile stalls.

``RecompileSentry`` wraps a jitted entry point and maintains the set of
distinct call signatures it has seen (by default: pytree structure +
per-leaf (shape, dtype, weak_type) — exactly the jit cache key's shape
axis). Three enforcement modes compose:

  * ``allowed``  — a predicate over the signature; violating calls raise
    ``RecompileGuardError`` *before* hitting the jit cache.
  * ``expected`` — a closed signature set; ``assert_signatures`` checks
    exact equality after a warmup / serve run (the ladder "compiles
    exactly its declared rung set" gate).
  * ``max_signatures`` — a hard cardinality cap for soak runs.

``check_cache_consistent`` cross-checks the wrapped function's own
``_cache_size()`` against the sentry's distinct-signature count: a cache
strictly larger than what the sentry saw means something below the
sentry key is splitting entries — the weak-dtype leak this module exists
to catch.

Serving integration: ``ServeConfig(guard_recompiles=True)`` wraps the
server's search_fn in a sentry keyed on (B, Mq, arg dtypes) and allows
only ladder rungs as batch sizes.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, Optional, Tuple, Union

import jax

__all__ = [
    "RecompileGuardError",
    "RecompileSentry",
    "abstract_signature",
    "ladder_signatures",
]


class RecompileGuardError(RuntimeError):
    """A jitted entry point compiled outside its declared signature set."""


def _leaf_spec(leaf: Any) -> Tuple:
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return (tuple(leaf.shape), str(leaf.dtype),
                bool(getattr(leaf, "weak_type", False)))
    if isinstance(leaf, (bool, int, float, str, bytes, type(None))):
        # python scalars are weak-typed under jit: keep the value's type
        # visible so an int/float flip shows up as a distinct signature
        return ("py", type(leaf).__name__, leaf)
    return ("py", type(leaf).__name__, repr(leaf))


def abstract_signature(*args, **kwargs) -> Tuple:
    """Hashable structural signature of a call: treedef + leaf specs.

    Mirrors the axes of jax.jit's cache key that shape-stable serving
    controls: pytree structure, per-leaf shape/dtype and — crucially —
    weak_type, the classic silent cache-splitter.
    """
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    return (str(treedef), tuple(_leaf_spec(x) for x in leaves))


def ladder_signatures(ladder: Iterable[int],
                      mq: Union[int, Iterable[int]]) -> frozenset:
    """The closed (B, Mq) signature set a serving ladder may compile."""
    mqs = (mq,) if isinstance(mq, int) else tuple(mq)
    return frozenset((int(b), int(m)) for b in ladder for m in mqs)


class RecompileSentry:
    """Wrap a callable; count and gate its distinct call signatures."""

    def __init__(self, fn: Callable, *, name: Optional[str] = None,
                 key_fn: Optional[Callable[..., Tuple]] = None,
                 expected: Optional[Iterable] = None,
                 allowed: Optional[Callable[[Tuple], bool]] = None,
                 max_signatures: Optional[int] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", repr(fn))
        self.key_fn = key_fn or abstract_signature
        self.expected = frozenset(expected) if expected is not None else None
        self.allowed = allowed
        self.max_signatures = max_signatures
        self.calls = 0
        self.signatures: Dict[Tuple, int] = {}  # signature -> call count

    def __call__(self, *args, **kwargs):
        key = self.key_fn(*args, **kwargs)
        # gate BEFORE recording: a rejected call never reaches the jit
        # cache, so it must not count as a seen signature either
        if self.allowed is not None and not self.allowed(key):
            raise RecompileGuardError(
                f"{self.name}: signature {key!r} rejected by the allowed "
                "predicate (off-ladder batch shape or dtype drift)")
        if self.expected is not None and key not in self.expected:
            raise RecompileGuardError(
                f"{self.name}: unexpected signature {key!r}; declared set "
                f"has {len(self.expected)} entries")
        self.calls += 1
        fresh = key not in self.signatures
        self.signatures[key] = self.signatures.get(key, 0) + 1
        if (self.max_signatures is not None and fresh
                and len(self.signatures) > self.max_signatures):
            raise RecompileGuardError(
                f"{self.name}: {len(self.signatures)} distinct signatures "
                f"> max_signatures={self.max_signatures} (unbounded jit "
                "cache growth)")
        return self.fn(*args, **kwargs)

    # -- post-run gates -----------------------------------------------------

    def assert_signatures(self, expected: Iterable) -> None:
        """Exact-set gate: the entry point compiled its declared rung set,
        the whole set, and nothing but the set."""
        want = frozenset(expected)
        got = frozenset(self.signatures)
        if got != want:
            extra = sorted(map(repr, got - want))
            missing = sorted(map(repr, want - got))
            raise RecompileGuardError(
                f"{self.name}: signature set mismatch; "
                f"unexpected={extra or 'none'} missing={missing or 'none'}")

    def check_cache_consistent(self) -> int:
        """Cross-check fn's jit cache size against the sentry count.

        Returns the cache size. A cache strictly larger than the distinct
        signatures seen here means jit is splitting entries on an axis
        the sentry key missed — in practice a weak-dtype or non-static
        argument leak below the serving layer.
        """
        cache_size = getattr(self.fn, "_cache_size", None)
        if cache_size is None:
            return len(self.signatures)
        n = cache_size()
        if n > len(self.signatures):
            raise RecompileGuardError(
                f"{self.name}: jit cache holds {n} entries but only "
                f"{len(self.signatures)} distinct signatures were seen — "
                "an argument axis outside the sentry key (weak dtype, "
                "non-static knob) is splitting the cache")
        return n

    def report(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "calls": self.calls,
            "n_signatures": len(self.signatures),
            "signatures": {repr(k): v for k, v in self.signatures.items()},
        }
