"""The paper's own architecture: HPC-ColPali over a ColQwen2.5-class
backbone (qwen2-1.5b config) + the retrieval pipeline knobs.

Shape cells (beyond the 40 assigned cells — these are the paper's system):
  train_256     — contrastive late-interaction training step, batch 256
  encode_corpus — offline indexing throughput: encode 1024 pages/step
  serve_query   — 64 queries against a 4.19M-doc quantized corpus sharded
                  over the full mesh (ADC MaxSim scan + global top-k merge)
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchSpec, ShapeCell
from repro.configs.lm_archs import QWEN2_1_5B
from repro.core.pipeline import HPCConfig
from repro.models.colpali import ColPaliConfig
from repro.models.transformer import LMConfig


@dataclasses.dataclass(frozen=True)
class HPCColPaliArch:
    encoder: ColPaliConfig
    hpc: HPCConfig
    corpus_docs: int = 4_194_304     # serve-cell corpus size (2^22 pages)
    kept_patches: int = 616          # ceil(1024 * 0.6) rounded to mult of 8
    serve_queries: int = 64
    top_k: int = 128

    @property
    def name(self) -> str:
        return "colpali-hpc"


COLPALI_SHAPES = (
    ShapeCell("train_256", "train", {"global_batch": 256}),
    ShapeCell("encode_corpus", "encode", {"global_batch": 1024}),
    ShapeCell("serve_query", "search",
              {"queries": 64, "corpus": 4_194_304}),
)

COLPALI_HPC = ArchSpec(
    arch_id="colpali-hpc",
    family="colpali",
    config=HPCColPaliArch(
        encoder=ColPaliConfig(
            name="colpali-hpc",
            backbone=QWEN2_1_5B.config,
            d_patch=1536,            # frozen vision-tower dim (stub frontend)
            proj_dim=128,            # paper: D=128
            n_patches=1024,          # 32x32 page grid (ColPali)
            query_len=32),
        hpc=HPCConfig(k=256, p=60.0, prune_side="doc", backend="flat",
                      rerank=32,
                      # corpus-scale codebook training: best-of-8 restarts,
                      # 16k-point k-means++ seeding. kmeans_minibatch is
                      # stochastic mini-batch Lloyd on a single host; on a
                      # sharded build (mesh=...) it instead bounds the
                      # streamed E-step to (65536, K) row blocks per device
                      # (full-batch statistics, bounded memory)
                      kmeans_restarts=8, kmeans_seed_batch=16384,
                      kmeans_minibatch=65536)),
    smoke_config=HPCColPaliArch(
        encoder=ColPaliConfig(
            name="colpali-smoke",
            backbone=LMConfig(
                name="colpali-smoke-bb", n_layers=2, d_model=48, n_heads=3,
                n_kv_heads=1, d_ff=96, vocab=128, head_dim=16,
                qkv_bias=True, q_chunk=16, loss_chunk=16),
            d_patch=24, proj_dim=16, n_patches=16, query_len=8),
        hpc=HPCConfig(k=16, p=60.0, prune_side="doc", backend="flat",
                      rerank=8, kmeans_iters=5, kmeans_restarts=2),
        corpus_docs=256, kept_patches=10, serve_queries=8, top_k=8),
    shapes=COLPALI_SHAPES,
    source="[this paper; ColQwen2.5 backbone = qwen2-1.5b family]",
    notes="the paper's system: K-Means K=256, p=60% doc-side pruning, "
          "quantized ADC scan + rerank 32",
)
