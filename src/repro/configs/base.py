"""Config schema: architectures x input-shape cells.

Every assigned architecture provides an ArchSpec with its exact public
config, a reduced smoke config (same family, small dims) for CPU tests,
and its assigned shape cells. launch/cells.py turns (ArchSpec, ShapeCell)
into a concrete (step_fn, input ShapeDtypeStructs, shardings) triple for
the dry-run, and the smoke tests run the same step functions on the smoke
config with tiny concrete batches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                    # train | prefill | decode | serve | candidates
    dims: Dict[str, int]         # seq_len / global_batch / n_nodes / ...
    skip: Optional[str] = None   # reason if this cell is skipped (DESIGN §6)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # lm | colpali | gnn | recsys
    config: Any                  # full production config
    smoke_config: Any            # reduced config (CPU tests)
    shapes: Tuple[ShapeCell, ...]
    source: str = ""             # [citation; verification tier]
    notes: str = ""


# Shared LM shape cells (assignment block). long_500k is overridden
# per-arch: only sub-quadratic archs run it.
def lm_shapes(long_skip: Optional[str]) -> Tuple[ShapeCell, ...]:
    return (
        ShapeCell("train_4k", "train",
                  {"seq_len": 4096, "global_batch": 256}),
        ShapeCell("prefill_32k", "prefill",
                  {"seq_len": 32768, "global_batch": 32}),
        ShapeCell("decode_32k", "decode",
                  {"seq_len": 32768, "global_batch": 128}),
        ShapeCell("long_500k", "decode",
                  {"seq_len": 524288, "global_batch": 1}, skip=long_skip),
    )


GNN_SHAPES = (
    # edge counts padded to a multiple of 4096 with phantom-node edges and
    # node counts padded to a multiple of 512 so both dims shard on every
    # mesh (docs/design.md §6); padding nodes are isolated and labelled -1.
    ShapeCell("full_graph_sm", "train",
              {"n_nodes": 3072, "n_edges": 12288, "d_feat": 1433,
               "n_classes": 7, "real_edges": 10556}),
    ShapeCell("minibatch_lg", "train",
              {"n_nodes": 170496, "n_edges": 172032, "d_feat": 602,
               "n_classes": 41, "real_edges": 168960,
               "graph_nodes": 232965, "graph_edges": 114615892,
               "batch_nodes": 1024, "fanout": (15, 10)}),
    ShapeCell("ogb_products", "train",
              {"n_nodes": 2449408, "n_edges": 61865984, "d_feat": 100,
               "n_classes": 47, "real_edges": 61859140}),
    ShapeCell("molecule", "train",
              {"n_graphs": 128, "nodes_per": 30, "edges_per": 64,
               "n_nodes": 3840, "n_edges": 8192, "d_feat": 28,
               "n_classes": 2}),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "candidates",
              {"batch": 1, "n_candidates": 1_000_000}),
)
