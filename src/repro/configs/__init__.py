"""Architecture configs (assigned pool + the paper's own) and registry."""

from repro.configs import registry  # noqa: F401
