"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchSpec
from repro.configs.colpali_hpc import COLPALI_HPC
from repro.configs.gnn_archs import PNA
from repro.configs.lm_archs import (GLM4_9B, KIMI_K2, LLAMA32_3B,
                                    LLAMA4_SCOUT, QWEN2_1_5B)
from repro.configs.recsys_archs import DCN_V2, DIEN, DIN, DLRM_MLPERF

ARCHS: Dict[str, ArchSpec] = {
    spec.arch_id: spec for spec in (
        GLM4_9B, QWEN2_1_5B, LLAMA32_3B, LLAMA4_SCOUT, KIMI_K2,
        PNA,
        DIN, DLRM_MLPERF, DIEN, DCN_V2,
        COLPALI_HPC,
    )
}

ASSIGNED = [a for a in ARCHS if a != "colpali-hpc"]


def get(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells(include_skipped: bool = False, include_colpali: bool = True):
    """Yield (arch_id, ShapeCell) for every cell."""
    for arch_id, spec in ARCHS.items():
        if arch_id == "colpali-hpc" and not include_colpali:
            continue
        for cell in spec.shapes:
            if cell.skip and not include_skipped:
                continue
            yield arch_id, cell
