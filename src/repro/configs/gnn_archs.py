"""Assigned GNN architecture: PNA [arXiv:2004.05718; paper]."""
from __future__ import annotations

from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import PNAConfig

PNA = ArchSpec(
    arch_id="pna",
    family="gnn",
    config=PNAConfig(
        name="pna", n_layers=4, d_hidden=75, d_feat=1433, n_classes=7,
        delta=2.5),
    smoke_config=PNAConfig(
        name="pna-smoke", n_layers=2, d_hidden=16, d_feat=12, n_classes=3,
        delta=2.0),
    shapes=GNN_SHAPES,
    source="[arXiv:2004.05718; paper]",
    notes="aggregators mean/max/min/std x scalers id/amplification/"
          "attenuation. d_feat/n_classes are overridden per shape cell "
          "(Cora/Reddit/ogbn-products/molecules). Paper technique: K-Means "
          "feature quantization applies; attention pruning N/A "
          "(attention-free arch — docs/design.md §5).",
)
