"""Assigned recsys architectures: DIN, DLRM-MLPerf, DIEN, DCN-v2.

Embedding-table vocabularies are the public Criteo lists (Terabyte for
DLRM-MLPerf, Kaggle for DCN-v2) and the public Amazon-Electronics counts
for DIN/DIEN. Rows are padded up to a multiple of 512 so tables row-shard
on the 16-way model axis of either production mesh (real row counts kept
in `notes`; padding rows are never indexed).
"""
from __future__ import annotations

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import RecsysConfig


def _pad512(rows):
    return tuple(-(-r // 512) * 512 for r in rows)


# Criteo Terabyte (MLPerf DLRM) per-feature cardinalities
CRITEO_TB = (39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63,
             38532951, 2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14,
             39979771, 25641295, 39664984, 585935, 12972, 108, 36)

# Criteo Kaggle per-feature cardinalities (DCN-v2 paper benchmark)
CRITEO_KAGGLE = (1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3,
                 93145, 5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652,
                 2173, 4, 7046547, 18, 15, 286181, 105, 142572)

# Amazon Electronics (DIN/DIEN public benchmark)
AMAZON_ITEMS = 63001

DLRM_MLPERF = ArchSpec(
    arch_id="dlrm-mlperf",
    family="recsys",
    config=RecsysConfig(
        name="dlrm-mlperf", family="dlrm", n_dense=13,
        table_rows=_pad512(CRITEO_TB), embed_dim=128,
        bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1)),
    smoke_config=RecsysConfig(
        name="dlrm-smoke", family="dlrm", n_dense=13,
        table_rows=(64, 32, 96, 48), embed_dim=16,
        bot_mlp=(32, 16), top_mlp=(32, 16, 1)),
    shapes=RECSYS_SHAPES,
    source="[arXiv:1906.00091; paper]",
    notes=f"MLPerf DLRM (Criteo 1TB), 26 tables, {sum(CRITEO_TB):,} real "
          "rows (~266M); dot interaction. Paper technique: table "
          "quantization + binary apply; attention pruning N/A.",
)

DCN_V2 = ArchSpec(
    arch_id="dcn-v2",
    family="recsys",
    config=RecsysConfig(
        name="dcn-v2", family="dcn", n_dense=13,
        table_rows=_pad512(CRITEO_KAGGLE), embed_dim=16,
        n_cross_layers=3, top_mlp=(1024, 1024, 512)),
    smoke_config=RecsysConfig(
        name="dcn-smoke", family="dcn", n_dense=13,
        table_rows=(64, 32, 96), embed_dim=8, n_cross_layers=2,
        top_mlp=(32, 16)),
    shapes=RECSYS_SHAPES,
    source="[arXiv:2008.13535; paper]",
    notes="cross-network v2 (full-rank), stacked; Criteo Kaggle vocab",
)

DIN = ArchSpec(
    arch_id="din",
    family="recsys",
    config=RecsysConfig(
        name="din", family="din", table_rows=_pad512((AMAZON_ITEMS,)),
        embed_dim=18, seq_len=100, attn_mlp=(80, 40), top_mlp=(200, 80)),
    smoke_config=RecsysConfig(
        name="din-smoke", family="din", table_rows=(256,), embed_dim=8,
        seq_len=12, attn_mlp=(16, 8), top_mlp=(16, 8)),
    shapes=RECSYS_SHAPES,
    source="[arXiv:1706.06978; paper]",
    notes="target attention over user history (Amazon Electronics vocab). "
          "Paper technique transfers fully: attention-guided history "
          "pruning (din_prune_p) + table quantization — docs/design.md §5.",
)

DIEN = ArchSpec(
    arch_id="dien",
    family="recsys",
    config=RecsysConfig(
        name="dien", family="dien", table_rows=_pad512((AMAZON_ITEMS,)),
        embed_dim=18, seq_len=100, gru_dim=108, attn_mlp=(80, 40),
        top_mlp=(200, 80)),
    smoke_config=RecsysConfig(
        name="dien-smoke", family="dien", table_rows=(256,), embed_dim=8,
        seq_len=12, gru_dim=16, attn_mlp=(16, 8), top_mlp=(16, 8)),
    shapes=RECSYS_SHAPES,
    source="[arXiv:1809.03672; unverified]",
    notes="GRU interest extraction + AUGRU evolution",
)
