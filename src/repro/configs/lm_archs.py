"""Assigned LM-family architecture configs (exact public configs).

long_500k policy (docs/design.md §6): glm4/qwen2/llama3.2/kimi-k2 are pure
full-attention per their public configs -> the 500k decode cell is skipped
for them; llama4-scout's public iRoPE design uses chunked-local attention
(chunk 8192, every 4th layer global) -> it runs long_500k.
"""
from __future__ import annotations

from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

_FULL_ATTN_SKIP = ("pure full-attention arch: O(S^2) prefill/O(S) dense "
                   "decode state at 524k is out of scope per assignment; "
                   "see docs/design.md §6")

GLM4_9B = ArchSpec(
    arch_id="glm4-9b",
    family="lm",
    config=LMConfig(
        name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, head_dim=128, qkv_bias=True,
        tie_embeddings=False, rope_theta=1e6, loss_chunk=256,
        activation_dtype="bfloat16"),
    smoke_config=LMConfig(
        name="glm4-9b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, head_dim=16, qkv_bias=True,
        tie_embeddings=False, q_chunk=16, loss_chunk=16),
    shapes=lm_shapes(long_skip=_FULL_ATTN_SKIP),
    source="[hf:THUDM/glm-4-9b; hf]",
    notes="dense, RoPE, GQA kv=2, QKV bias",
)

QWEN2_1_5B = ArchSpec(
    arch_id="qwen2-1.5b",
    family="lm",
    config=LMConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_ff=8960, vocab=151936, head_dim=128, qkv_bias=True,
        tie_embeddings=True, rope_theta=1e6, loss_chunk=256,
        activation_dtype="bfloat16"),
    smoke_config=LMConfig(
        name="qwen2-1.5b-smoke", n_layers=2, d_model=48, n_heads=3,
        n_kv_heads=1, d_ff=96, vocab=128, head_dim=16, qkv_bias=True,
        tie_embeddings=True, q_chunk=16, loss_chunk=16),
    shapes=lm_shapes(long_skip=_FULL_ATTN_SKIP),
    source="[arXiv:2407.10671; hf]",
    notes="dense, GQA kv=2, QKV bias; ColQwen2.5 backbone family "
          "(12 heads don't divide the 16-way model axis: heads replicate, "
          "fused qkv_out=1536 still shards — docs/design.md §4)",
)

LLAMA32_3B = ArchSpec(
    arch_id="llama3.2-3b",
    family="lm",
    config=LMConfig(
        name="llama3.2-3b", n_layers=28, d_model=3072, n_heads=24,
        n_kv_heads=8, d_ff=8192, vocab=128256, head_dim=128,
        tie_embeddings=True, rope_theta=500000.0, loss_chunk=256,
        activation_dtype="bfloat16"),
    smoke_config=LMConfig(
        name="llama3.2-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=128, head_dim=16,
        tie_embeddings=True, q_chunk=16, loss_chunk=16),
    shapes=lm_shapes(long_skip=_FULL_ATTN_SKIP),
    source="[hf:meta-llama/Llama-3.2-1B; unverified]",
    notes="small llama3; GQA kv=8",
)

LLAMA4_SCOUT = ArchSpec(
    arch_id="llama4-scout-17b-a16e",
    family="lm",
    config=LMConfig(
        name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
        n_kv_heads=8, d_ff=8192, vocab=202048, head_dim=128,
        tie_embeddings=False, rope_theta=500000.0,
        n_experts=16, moe_top_k=1, moe_d_ff=8192, n_shared_experts=1,
        attn_chunk=8192, global_every=4, loss_chunk=256, q_chunk=128,
        activation_dtype="bfloat16"),
    smoke_config=LMConfig(
        name="llama4-scout-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=0, vocab=128, head_dim=16, tie_embeddings=False,
        n_experts=4, moe_top_k=1, moe_d_ff=96, n_shared_experts=1,
        attn_chunk=8, global_every=4, q_chunk=8, loss_chunk=16),
    shapes=lm_shapes(long_skip=None),   # chunked-local attn -> runs 500k
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
    notes="MoE 16e top-1 + shared expert; iRoPE chunked-local attention "
          "(chunk 8192, every 4th layer global) -> long_500k runs",
)

KIMI_K2 = ArchSpec(
    arch_id="kimi-k2-1t-a32b",
    family="lm",
    config=LMConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, d_ff=0, vocab=163840, head_dim=112,
        tie_embeddings=False, rope_theta=500000.0,
        n_experts=384, moe_top_k=8, moe_d_ff=2048, loss_chunk=256,
        q_chunk=256,
        param_dtype="bfloat16", activation_dtype="bfloat16"),
    smoke_config=LMConfig(
        name="kimi-k2-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=0, vocab=128, head_dim=16, tie_embeddings=False,
        n_experts=8, moe_top_k=2, moe_d_ff=32, q_chunk=16, loss_chunk=16),
    shapes=lm_shapes(long_skip=_FULL_ATTN_SKIP),
    source="[arXiv:2501.kimi2; unverified]",
    notes="1T-param MoE 384e top-8 (paper-table config). Trains with bf16 "
          "params + int8 Adam moments, ZeRO-sharded (docs/design.md §6): fp32 "
          "AdamW (16 B/param = 16.5 TB) cannot fit either mesh.",
)
