"""Serve a quantized HPC-ColPali index behind the asyncio
continuous-batching server (power-of-two padding ladder) and fire
open-loop Poisson traffic at it.

  PYTHONPATH=src python examples/serve_retrieval.py
(thin wrapper over repro.launch.serve with demo-sized defaults; pass
--single-shape to feel the v1 pad-to-max-batch latency difference)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--n-docs", "2048", "--queries", "128", "--backend", "flat",
          "--k", "256", "--p", "60", "--max-batch", "8",
          "--rate-qps", "150"] + sys.argv[1:])
