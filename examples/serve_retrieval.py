"""Serve a quantized HPC-ColPali index behind the continuous-batching
retrieval server and fire concurrent client requests at it.

  PYTHONPATH=src python examples/serve_retrieval.py
(thin wrapper over repro.launch.serve with demo-sized defaults)
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--n-docs", "2048", "--queries", "128", "--backend", "flat",
          "--k", "256", "--p", "60", "--max-batch", "8"])
