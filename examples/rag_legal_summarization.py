"""RAG legal-summarisation demo (paper Table V, §V-C):

  1. builds a fact-grounded synthetic legal corpus,
  2. trains a small generator LM to answer fact queries from retrieved
     context (a few hundred steps),
  3. compares retrievers (ColPali-Full vs HPC-compressed vs binary vs a
     weak single-vector baseline) on ROUGE-L, *exactly measured*
     hallucination rate, and end-to-end latency.

  PYTHONPATH=src python examples/rag_legal_summarization.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import rag_bench


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300,
                    help="generator training steps")
    args = ap.parse_args()
    rows = rag_bench.run(steps=args.steps)
    print("\nsummary (paper Table V structure):")
    print(f"{'retriever':22s} {'ROUGE-L':>8s} {'halluc%':>8s} "
          f"{'ms/query':>9s}")
    for r in rows:
        print(f"{r['retriever']:22s} {r['rouge_l']:8.3f} "
              f"{r['hallucination']*100:8.1f} {r['latency_ms']:9.1f}")


if __name__ == "__main__":
    main()
