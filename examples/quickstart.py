"""Quickstart: build an HPC-ColPali index over a synthetic corpus, query
it in every mode, and print the quality/storage trade-off.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import retrieval_metrics
from repro.data import synthetic
from repro.retrieval import Corpus, HPCConfig, Query, Retriever


def main():
    k_data, k_build = jax.random.split(jax.random.PRNGKey(0))
    print("building synthetic corpus (1024 docs x 32 patches x 128 dim)...")
    spec = synthetic.CorpusSpec(n_docs=1024, n_queries=64, n_topics=24,
                                patches_per_topic=10, noise=0.2,
                                salient_frac=0.4)
    data = synthetic.make_retrieval_corpus(k_data, spec)
    corpus = Corpus(data.doc_patches, data.doc_mask, data.doc_salience)
    queries = Query(data.query_patches, data.query_mask, data.query_salience)

    configs = {
        "ColPali-Full (fp32)": HPCConfig(backend="float_flat",
                                         prune_side="none"),
        "HPC quantized K=256 p=60": HPCConfig(k=256, p=60.0,
                                              backend="flat",
                                              prune_side="doc",
                                              rerank=32),
        "HPC binary K=512": HPCConfig(k=512, p=60.0, backend="hamming",
                                      prune_side="doc"),
    }
    for name, cfg in configs.items():
        retriever = Retriever(cfg)
        t0 = time.perf_counter()
        state = retriever.build(k_build, corpus)
        jax.block_until_ready(state.codebook)
        t_build = time.perf_counter() - t0

        t0 = time.perf_counter()
        _, ids = retriever.search(state, queries, k=10)
        ids = jax.block_until_ready(ids)
        t_query = (time.perf_counter() - t0) / 64 * 1e3

        m = retrieval_metrics(np.asarray(ids), np.asarray(data.relevance))
        sb = retriever.storage_bytes(state)
        print(f"{name:28s} nDCG@10={m['ndcg@10']:.3f} "
              f"R@10={m['recall@10']:.3f} | payload "
              f"{sb['payload']/1e6:7.2f} MB | build {t_build:5.1f}s | "
              f"{t_query:6.2f} ms/query")


if __name__ == "__main__":
    main()
