"""End-to-end training driver: train a ColPali-style retrieval encoder with
the in-batch contrastive late-interaction loss, through the fault-tolerant
loop (checkpoint/resume, NaN guard, prefetch pipeline).

  # quick demo (~5M params, a couple of minutes on CPU):
  PYTHONPATH=src python examples/train_retriever.py --preset small --steps 120

  # the assignment's ~100M-param run (use a few hundred steps):
  PYTHONPATH=src python examples/train_retriever.py --preset 100m --steps 300

After training it builds an HPC index with the *trained* encoder +
attention salience and reports retrieval quality vs the untrained encoder.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import retrieval_metrics
from repro.retrieval import Corpus, HPCConfig, Query, Retriever
from repro.data import synthetic
from repro.data.pipeline import PrefetchPipeline
from repro.models import colpali, transformer as T
from repro.optim import optimizer as opt
from repro.train import loop as train_loop

PRESETS = {
    # ~5M params: CPU-friendly demo
    "small": T.LMConfig(name="enc-small", n_layers=4, d_model=128,
                        n_heads=4, n_kv_heads=2, d_ff=256, vocab=2048,
                        q_chunk=32, loss_chunk=32),
    # ~100M params (the assignment's end-to-end scale)
    "100m": T.LMConfig(name="enc-100m", n_layers=12, d_model=768,
                       n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768,
                       q_chunk=64, loss_chunk=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--n-patches", type=int, default=12)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_retriever_ckpt")
    args = ap.parse_args()

    bb = PRESETS[args.preset]
    enc = colpali.ColPaliConfig(backbone=bb, d_patch=64, proj_dim=64,
                                n_patches=args.n_patches, query_len=8)
    print(f"encoder params: {enc.param_count()/1e6:.1f}M")

    k_data, k_init, key = jax.random.split(jax.random.PRNGKey(0), 3)
    # a fixed topic structure shared by train batches and the eval corpus
    spec = synthetic.CorpusSpec(n_docs=512, n_queries=64,
                                n_patches=args.n_patches, n_q_patches=8,
                                dim=enc.d_patch, n_topics=16)
    eval_data = synthetic.make_retrieval_corpus(k_data, spec)

    def batches():
        i = 0
        while True:
            k = jax.random.fold_in(key, i)
            # contrastive pairs: queries are noisy views of their doc
            pick = jax.random.randint(k, (args.batch,), 0, 512)
            docs = eval_data.doc_patches[pick]
            qk = jax.random.fold_in(k, 1)
            sel = jax.random.randint(qk, (args.batch, 8), 0,
                                     args.n_patches)
            qp = jnp.take_along_axis(docs, sel[..., None], axis=1)
            nk = jax.random.fold_in(k, 2)
            qp = qp + 0.1 * jax.random.normal(nk, qp.shape)
            # query tokens: hash of the topic (toy textual query)
            qt = (pick[:, None] * 7 + jnp.arange(enc.query_len)[None]) \
                % bb.vocab
            yield {
                "query_tokens": qt.astype(jnp.int32),
                "query_mask": jnp.ones((args.batch, enc.query_len), bool),
                "doc_patches": docs,
                "doc_mask": jnp.ones((args.batch, args.n_patches), bool),
            }
            i += 1

    ocfg = opt.AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=10,
                           weight_decay=0.01)
    params = colpali.init(k_init, enc)
    state = opt.init(ocfg, params)

    def eval_quality(p):
        d_emb, d_sal = colpali.encode_doc(p, eval_data.doc_patches,
                                          eval_data.doc_mask, enc)
        # queries: encode their patch views through the same tower
        q_emb, q_sal = colpali.encode_doc(p, eval_data.query_patches,
                                          eval_data.query_mask, enc)
        r = Retriever(HPCConfig(k=64, p=60.0, backend="flat",
                                prune_side="doc", kmeans_iters=10,
                                rerank=32))
        state = r.build(key, Corpus(d_emb, eval_data.doc_mask, d_sal))
        _, ids = r.search(state, Query(q_emb, eval_data.query_mask, q_sal),
                          k=10)
        return retrieval_metrics(np.asarray(ids),
                                 np.asarray(eval_data.relevance))

    print("quality before training:", eval_quality(params))

    jit_step = jax.jit(lambda p, s, b: colpali.train_step(p, s, b, enc,
                                                          ocfg))
    pipe = PrefetchPipeline(batches(), depth=2)
    cfg = train_loop.LoopConfig(total_steps=args.steps,
                                ckpt_every=max(20, args.steps // 3),
                                ckpt_dir=args.ckpt_dir,
                                log_every=max(1, args.steps // 10))
    out = train_loop.run(jit_step, params, state, pipe, cfg)
    pipe.close()
    print(f"loop stats: {out['stats']} | pipeline: {pipe.stats}")
    print("quality after training: ", eval_quality(out["params"]))


if __name__ == "__main__":
    main()
